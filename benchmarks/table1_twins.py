"""Paper Table I: twin parameters fit from wind-tunnel experiments on the
three telemetry pipeline variants (our measured CPU numbers, alongside the
paper's published cloud numbers for reference)."""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern
from repro.core.twin import fit_simple_twin
from repro.pipelines.telemetry import (TELEMETRY_VARIANTS,
                                       make_telemetry_dataset,
                                       make_telemetry_pipeline)

PAPER = {  # variant -> (max rec/s, cents/hr, avg latency s)
    "blocking-write": (1.95, 0.82, 0.15),
    "no-blocking-write": (6.15, 7.03, 0.06),
    "cpu-limited": (0.66, 0.27, 0.29),
}


def run(records: int = 40, peak_rate: float = 120.0, duration_s: float = 3.0
        ) -> List[Dict]:
    ds = make_telemetry_dataset(records, seed=11)
    rows = []
    for variant in TELEMETRY_VARIANTS:
        pipe = make_telemetry_pipeline(variant,
                                       blob_dir=tempfile.mkdtemp())
        load = LoadPattern.ramp("ramp", duration_s=duration_s,
                                peak_rate=peak_rate)
        t0 = time.perf_counter()
        res = Experiment(f"t1-{variant}", pipe, load, ds,
                         drain_timeout_s=120).run()
        wall = time.perf_counter() - t0
        tw = fit_simple_twin(res)
        p = PAPER[variant]
        rows.append({
            "model": variant,
            "max_rps": round(tw.max_rps, 2),
            "usd_per_hr": round(tw.usd_per_hour, 4),
            "avg_latency_ms": round(tw.base_latency_s * 1e3, 3),
            "policy": tw.policy,
            "paper_rps": p[0], "paper_cents_hr": p[1],
            "paper_latency_s": p[2],
            "wall_s": round(wall, 2),
        })
    return rows


def main() -> List[str]:
    rows = run()
    lines = []
    for r in rows:
        lines.append(f"table1/{r['model']},{r['wall_s']*1e6:.0f},"
                     f"rps={r['max_rps']};usd_hr={r['usd_per_hr']};"
                     f"lat_ms={r['avg_latency_ms']}")
    return lines


if __name__ == "__main__":
    from repro.core.report import render_table
    print(render_table(run(), "Table I (measured twins vs paper)"))

"""Paper Table IV: monthly cloud/network/storage costs for the nominal
no-blocking model at 3- vs 6-month retention. Record size calibrated so the
3-month storage-year total matches the published 552.56 USD."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.cost import CostModel
from repro.core.traffic import TrafficModel
from repro.core.twin import SimpleTwin
from repro.core.whatif import retention_whatif

# Calibrated from Table IV's storage column: avg stored ~151.8 GB at 91-day
# retention over a ~44M-record year -> 0.0141 MB per record transmission.
# (The paper's own network column implies ~0.0007 MB/record — its net and
# storage figures are mutually inconsistent; we calibrate to storage, the
# dominant cost, and report the network overshoot. See EXPERIMENTS.md.)
RECORD_MB = 0.0141

PAPER_TOTALS_3MO = {"cloud": 614.19, "network": 6.01, "storage": 552.56}


def run() -> Dict[int, List[Dict]]:
    tw = SimpleTwin("non-block", 6.15, 0.0703, 0.06)
    nom = TrafficModel.honda_default("nom", R=3.5, G=1.0)
    return retention_whatif(tw, nom, RECORD_MB, retentions_days=(91, 182),
                            cost_model=CostModel())


def main() -> List[str]:
    t0 = time.perf_counter()
    tables = run()
    us = (time.perf_counter() - t0) * 1e6
    lines = []
    for ret, rows in tables.items():
        tot_cloud = sum(r["cloud_usd"] for r in rows)
        tot_net = sum(r["network_usd"] for r in rows)
        tot_stor = sum(r["storage_usd"] for r in rows)
        lines.append(
            f"table4/retention_{ret}d,{us:.0f},"
            f"cloud={tot_cloud:.2f};net={tot_net:.2f};storage={tot_stor:.2f};"
            f"total={tot_cloud + tot_net + tot_stor:.2f}")
    return lines


if __name__ == "__main__":
    from repro.core.report import render_table
    for ret, rows in run().items():
        print(render_table(rows, f"Table IV — {ret}-day retention"))
    print("paper 3-mo totals:", PAPER_TOTALS_3MO)

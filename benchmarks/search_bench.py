"""Policy-search benchmark: one-dispatch multi-start vs a serial loop,
and gradient search vs the exhaustive 4096-scenario grid it replaces.

Two measurements on the same full-year problem (autoscale capacity
planning at +40% traffic under a 2h/95% latency SLO):

* **batched vs serial multi-start** — ``search(restarts=K)`` runs all K
  restarts as lanes of ONE grad-of-scan dispatch; the serial baseline
  calls ``search(restarts=1)`` K times. Same total restarts, same steps
  (polish disabled in both arms so the kernel dominates the clock).
* **search vs exhaustive grid** — the optimizer (with its exact
  re-check + polish) against ``whatif.run_grid`` over the SAME space's
  4096-point factorial sweep, comparing wall-clock AND answer quality
  (annual cost of the best feasible configuration found by each).

``main_stream`` adds the streaming-objective rows: ONE
``value_and_grad`` step of the chance-constrained lane objective at
frontier scale (K=8 restarts x S=4 traffics x F=32 fault futures =
1024 lanes, T=8736 hourly bins), streamed in-carry fold vs
materialize-then-reduce.

All timings come from ``repro.obs``: the multi-start / vs-grid arms
are ``obs.timed`` spans, and the streaming rows are
``obs.profile_dispatch`` profiles — an AOT compile-vs-execute split
plus the compiled program's peak temp bytes (``jax.stages``
``memory_analysis``), recorded as ``dispatch.*`` spans. The JSON rows
are those spans/profiles serialized, not private ``perf_counter``
pairs.

Writes ``BENCH_search.json`` and emits the harness CSV rows.

  PYTHONPATH=src python benchmarks/search_bench.py
  PYTHONPATH=src python -m benchmarks.run search
  PYTHONPATH=src python -m benchmarks.run search-stream
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import numpy as np

from repro import obs
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import make_twin
from repro.core.whatif import run_grid
from repro.search import evaluate_exact, search, search_space

RESTARTS = (1, 4, 8)
STEPS = 60
COARSEN = 4                 # gradient-loop bins; re-checks stay hourly
GRID_POINTS = 4096
OUT_JSON = os.environ.get("BENCH_SEARCH_JSON", "BENCH_search.json")


def _problem():
    traffic = TrafficModel.honda_default("high(+40%)", R=3.5, G=1.4)
    slo = SLO(limit_s=2 * 3600, met_fraction=0.95)
    base = make_twin("auto", "autoscale", max_rps=1.9512,
                     usd_per_hour=0.0082, base_latency_s=0.15,
                     max_instances=8, scale_up_hours=2)
    space = search_space(base, ("max_instances", "scale_up_hours"))
    return space, traffic, slo


def bench() -> Dict:
    space, traffic, slo = _problem()
    kw = dict(steps=STEPS, coarsen=COARSEN, polish_rounds=0)

    # -- batched vs serial multi-start ----------------------------------
    records = []
    for k in RESTARTS:
        search(space, [traffic], slo, restarts=k, seed=0, **kw)  # compile
        batched_s = []
        for rep in (1, 2, 3):
            with obs.timed("bench.search_batched", restarts=k) as tm:
                res = search(space, [traffic], slo, restarts=k,
                             seed=rep, **kw)
            batched_s.append(tm.elapsed)
        batched = min(batched_s)
        with obs.timed("bench.search_serial", restarts=k) as tm:
            for i in range(k):
                res1 = search(space, [traffic], slo, restarts=1,
                              seed=1 + i, **kw)
        serial_s = tm.elapsed
        records.append({"restarts": k, "steps": STEPS,
                        "batched_s": round(batched, 3),
                        "serial_s": round(serial_s, 3),
                        "speedup": round(serial_s / batched, 2),
                        "batched_cost": round(float(res.cost_usd), 3)})
    del res1

    # -- search vs exhaustive grid, equal answer quality ----------------
    # full resolution here (coarsen=1 + polish): the claim under test is
    # that the optimizer's answer costs no more than the sweep's best row
    with obs.timed("bench.search_full") as tm:
        full = search(space, [traffic], slo, restarts=6, steps=80, seed=0)
    search_s = tm.elapsed
    with obs.timed("bench.grid_sweep", points=GRID_POINTS) as tm:
        twins = space.grid(GRID_POINTS)
        rows = run_grid(twins, [traffic], slo=slo)
        feas = [r for r in rows if r.slo_met]
        grid_cost = min(r.total_cost_usd for r in feas) if feas \
            else float("inf")
    grid_s = tm.elapsed

    return {
        "device": jax.devices()[0].platform,
        "steps": STEPS,
        "coarsen": COARSEN,
        "multi_start": records,
        "speedup_at_max_k": records[-1]["speedup"],
        "vs_grid": {
            "grid_points": GRID_POINTS,
            "search_s": round(search_s, 3),
            "grid_s": round(grid_s, 3),
            "search_cost_usd": round(float(full.cost_usd), 4),
            "grid_cost_usd": round(float(grid_cost), 4),
            "search_feasible": bool(full.feasible),
            # "equal answer quality": the optimizer's config costs no
            # more than the best feasible row of the exhaustive sweep
            "search_beats_grid": bool(full.cost_usd <= grid_cost),
        },
    }


STREAM_K, STREAM_S, STREAM_F, STREAM_T = 8, 4, 32, 8736


def bench_stream() -> Dict:
    from repro import faults
    from repro.core.twin import AGG_SLO_LATENCY
    from repro.search.objective import lane_objective

    space, traffic, slo = _problem()
    k, s, f, t = STREAM_K, STREAM_S, STREAM_F, STREAM_T
    lanes = k * s * f
    rng = np.random.default_rng(0)

    hl = traffic.hourly_loads()[:t].astype(np.float32)
    loads = np.stack([hl * (0.8 + 0.2 * i) for i in range(s)])  # [S, T]
    loads_block = np.tile(np.repeat(loads, f, axis=0), (k, 1))  # [L, T]
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=20),
               faults.disconnect(disconnect_frac=(0.2, 0.5))),
        n_futures=f, seed=7)
    caps = np.asarray(faults.sample_futures(sched, t, 1.0).cap,
                      np.float32)                               # [F, T]
    caps_block = np.tile(caps, (k * s, 1))                      # [L, T]
    base = space.base
    params = np.tile(base.padded_params().astype(np.float32), (lanes, 1))
    params = (params * rng.uniform(0.9, 1.1, params.shape)) \
        .astype(np.float32)
    slo_lane = np.full((lanes,), float(slo.limit_s), np.float32)
    args = (1.0, np.int32(base.policy_index), slo_lane,
            AGG_SLO_LATENCY, float(slo.met_fraction), 100.0, 50.0, 1.2)

    params, loads_block, caps_block = map(
        jax.numpy.asarray, (params, loads_block, caps_block))

    def one_step(stream):
        def loss(p):
            return lane_objective(p, loads_block, *args,
                                  caps_block=caps_block,
                                  stream=stream)[0].sum()
        return jax.jit(jax.value_and_grad(loss))

    rows = []
    for name, stream in (("streamed", True), ("materialized", False)):
        fn = one_step(stream)
        # AOT profile: timed compile, memory/cost analyses, best-of-3
        # execute — recorded as a dispatch.search.stream_* obs span
        (v, g), prof = obs.profile_dispatch(
            f"search.stream_{name}", fn, params, reps=3,
            lanes=lanes, t_bins=t)
        peak = prof.peak_temp_bytes
        rows.append({"path": name,
                     "grad_step_s": round(prof.execute_s, 3),
                     "compile_s": round(prof.compile_s, 3),
                     "peak_temp_mb": (round(peak / 2**20, 1)
                                      if peak is not None else None),
                     "objective_sum": float(v),
                     "grad_l2": round(float(
                         jax.numpy.linalg.norm(g)), 3)})
    st, mt = rows
    return {
        "device": jax.devices()[0].platform,
        "lanes": lanes, "t_bins": t,
        "restarts": k, "traffics": s, "fault_futures": f,
        "rows": rows,
        "speedup": round(mt["grad_step_s"] / st["grad_step_s"], 2),
        "peak_temp_ratio": (round(mt["peak_temp_mb"]
                                  / max(st["peak_temp_mb"], 0.1), 1)
                            if None not in (st["peak_temp_mb"],
                                            mt["peak_temp_mb"])
                            else None),
    }


def main_stream() -> List[str]:
    r = bench_stream()
    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["stream"] = r
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    lines = []
    for row in r["rows"]:
        lines.append(f"search/stream_{row['path']},"
                     f"{row['grad_step_s'] * 1e6:.0f},"
                     f"compile_s={row['compile_s']};"
                     f"peak_temp_mb={row['peak_temp_mb']};"
                     f"lanes={r['lanes']};t={r['t_bins']}")
    lines.append(f"search/stream_speedup,0,"
                 f"x{r['speedup']}-wall;"
                 f"peak_ratio={r['peak_temp_ratio']};json={OUT_JSON}")
    return lines


def main() -> List[str]:
    r = bench()
    with open(OUT_JSON, "w") as f:
        json.dump(r, f, indent=2, sort_keys=True)
    lines = []
    for rec in r["multi_start"]:
        lines.append(f"search/fit_k{rec['restarts']},"
                     f"{rec['batched_s'] * 1e6:.0f},"
                     f"x{rec['speedup']}-vs-serial;steps={rec['steps']}")
    vg = r["vs_grid"]
    lines.append(f"search/vs_grid_{vg['grid_points']},"
                 f"{vg['search_s'] * 1e6:.0f},"
                 f"grid={vg['grid_s']}s;search=${vg['search_cost_usd']};"
                 f"grid=${vg['grid_cost_usd']};"
                 f"beats={vg['search_beats_grid']};json={OUT_JSON}")
    return lines


if __name__ == "__main__":
    result = bench()
    with open(OUT_JSON, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
    print(json.dumps(result, indent=2, sort_keys=True))

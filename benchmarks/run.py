# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness runner.

  PYTHONPATH=src python -m benchmarks.run          # all tables
  PYTHONPATH=src python -m benchmarks.run table2   # one table

Tables map to the paper: table1 (twin parameters), table2 (year
simulations), table3 (engineering comparison), table4 (retention costs),
plus the roofline table over the assigned (arch x shape) grid, a core
micro-benchmark of the wind-tunnel primitives, the twin-calibration
fit benchmark (which also writes BENCH_calibrate.json), the
grid-backend sweep ``grid-pallas`` — XLA vs Pallas-interpret at
64/256/1024 scenarios (writes BENCH_grid_pallas.json) — and the
streaming sweep ``grid-stream`` — series vs aggregate ``simulate_grid``
at 1024/8192/65536 full-year scenarios (writes BENCH_grid_stream.json) —
the sharded-engine sweep ``grid-shard`` — the policy-uniform block
engine at 65536/262144/1048576 full-year scenarios over a 1/2/4-device
scenario mesh (writes BENCH_grid_shard.json; run with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` or pass
``grid-shard`` on the command line, which sets it before jax loads) —
the device-resident histogram sweep ``grid-device`` — the fully
in-graph aggregate engine (f64 ``segment_sum`` histogram, no host
binning, duplicate scenario rows deduped at dispatch) at
1024/65536/1048576 full-year scenarios, single-device + 1/2/4 mesh,
plus an all-distinct control row, vs the PR 6 host-binned baseline
(writes BENCH_grid_device.json; same XLA_FLAGS note as
``grid-shard``) — and the policy-search
benchmark ``search`` — one-dispatch K-restart search vs a serial loop,
and search vs the exhaustive 4096-point grid
(writes BENCH_search.json) — plus ``search-stream`` — one
chance-constrained ``value_and_grad`` step at frontier scale (1024
lanes x 8736 bins), streamed in-carry objective vs
materialize-then-reduce, wall clock and peak temp bytes (merges a
"stream" key into BENCH_search.json).
"""
from __future__ import annotations

import sys
import time


def _micro() -> list:
    """Micro-benchmarks of wind-tunnel primitives (span overhead etc.)."""
    from repro.core.spans import SpanCollector, span
    from repro.core.loadpattern import LoadPattern
    col = SpanCollector()
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        with span("x", col):
            pass
    span_us = (time.perf_counter() - t0) / n * 1e6
    lp = LoadPattern.ramp("r", 120, 40)
    t0 = time.perf_counter()
    for i in range(200):
        lp.records_between(i % 100, i % 100 + 1)
    lp_us = (time.perf_counter() - t0) / 200 * 1e6
    return [f"micro/span_overhead,{span_us:.2f},per-span",
            f"micro/loadpattern_integral,{lp_us:.2f},per-second-window"]


TABLES = {
    "micro": _micro,
    "table1": lambda: __import__("benchmarks.table1_twins",
                                 fromlist=["main"]).main(),
    "table2": lambda: __import__("benchmarks.table2_sims",
                                 fromlist=["main"]).main(),
    "table3": lambda: __import__("benchmarks.table3_experiments",
                                 fromlist=["main"]).main(),
    "table4": lambda: __import__("benchmarks.table4_retention",
                                 fromlist=["main"]).main(),
    "grid": lambda: __import__("benchmarks.grid_bench",
                               fromlist=["main"]).main(),
    "grid-pallas": lambda: __import__("benchmarks.grid_bench",
                                      fromlist=["main_pallas"]).main_pallas(),
    "grid-stream": lambda: __import__("benchmarks.grid_bench",
                                      fromlist=["main_stream"]).main_stream(),
    "grid-shard": lambda: __import__("benchmarks.grid_bench",
                                     fromlist=["main_shard"]).main_shard(),
    "grid-device": lambda: __import__("benchmarks.grid_bench",
                                      fromlist=["main_device"]).main_device(),
    "calibrate": lambda: __import__("benchmarks.calibrate_bench",
                                    fromlist=["main"]).main(),
    "faults": lambda: __import__("benchmarks.faults_bench",
                                 fromlist=["main"]).main(),
    "search": lambda: __import__("benchmarks.search_bench",
                                 fromlist=["main"]).main(),
    "search-stream": lambda: __import__(
        "benchmarks.search_bench",
        fromlist=["main_stream"]).main_stream(),
    "roofline": lambda: __import__("benchmarks.roofline_bench",
                                   fromlist=["main"]).main(),
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for name in which:
        fn = TABLES.get(name)
        if fn is None:
            print(f"{name},0,unknown-table")
            continue
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:   # noqa: BLE001 — report, keep going
            print(f"{name}/error,0,{type(e).__name__}:{str(e)[:120]}")


if __name__ == "__main__":
    main()

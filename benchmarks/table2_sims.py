"""Paper Table II: six (twin x traffic) year-long simulations using the
paper's published twin parameters; validated against the published costs,
SLO pattern and backlogs. The whole grid runs as one vmapped scan (see
benchmarks/grid_bench.py for the looped-vs-vmapped comparison). Also times
simulate_year ("the simulation is quite fast" — here ~1 ms/year after
jit)."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import SimpleTwin
from repro.core.simulate import simulate_year
from repro.core.whatif import run_grid, table2_rows

TWINS = [
    SimpleTwin("block", 1.9512, 0.0082, 0.15),
    SimpleTwin("non-block", 6.15, 0.0703, 0.06),
    SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29),
]
PAPER_COST = {"nom block": 71.87, "nom non-block": 614.19,
              "nom cpu-lim": 50.56, "high block": 74.71,
              "high non-block": 614.19, "high cpu-lim": 63.98}
PAPER_SLO = {"nom block": True, "nom non-block": True, "nom cpu-lim": False,
             "high block": False, "high non-block": True,
             "high cpu-lim": False}


def run() -> List[Dict]:
    nom = TrafficModel.honda_default("nom", R=3.5, G=1.0)
    high = TrafficModel.honda_default("high", R=3.5, G=1.5)
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    sims = run_grid(TWINS, [nom, high], slo=slo)
    rows = table2_rows(sims)
    for r in rows:
        r["paper_cost"] = PAPER_COST[r["run"]]
        r["cost_err_pct"] = round(100 * abs(r["cost_usd"] - r["paper_cost"])
                                  / r["paper_cost"], 2)
        r["slo_matches_paper"] = (r["slo_met"] == PAPER_SLO[r["run"]])
    return rows


def sim_speed_us() -> float:
    nom = TrafficModel.honda_default("nom")
    loads = nom.hourly_loads()
    tw = TWINS[0]
    simulate_year(tw, loads)                       # warm the jit cache
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        simulate_year(tw, loads)
    return (time.perf_counter() - t0) / n * 1e6


def main() -> List[str]:
    us = sim_speed_us()
    rows = run()
    lines = [f"table2/simulate_year,{us:.0f},8736h-fifo-scan"]
    for r in rows:
        lines.append(
            f"table2/{r['run'].replace(' ', '_')},{us:.0f},"
            f"cost={r['cost_usd']};paper={r['paper_cost']};"
            f"err_pct={r['cost_err_pct']};slo_match={r['slo_matches_paper']}")
    return lines


if __name__ == "__main__":
    from repro.core.report import render_table
    print(render_table(run(), "Table II (simulations vs paper)"))
    print(f"simulate_year: {sim_speed_us():.0f} us per simulated year")

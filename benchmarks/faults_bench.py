"""Chaos-suite benchmark: fault-perturbed vs benign aggregate grids.

The fault layer (``repro.faults``) threads per-bin capacity multipliers,
a reconnect-flood backlog queue and two in-carry attribution counters
through the streaming-aggregate scan. This bench measures what that
costs: the SAME expanded row count N runs once benign (``faults=None``
over N scenarios) and once as a chaos suite (N/F base scenarios x F=4
sampled fault futures — outages, disconnect/reconnect floods, brownouts
and bursts), both through ``simulate_grid(return_series=False)`` with
the full 8736-hour year per row.

At N = 65536 this is the acceptance run: a 65,536-scenario full-year
chaos grid (4 futures per base scenario) completing on this CPU
container through the blocked aggregate path. Writes
``BENCH_faults.json`` with per-size wall-clocks and the fault/benign
overhead ratio, and emits the harness CSV rows. Timing loops record
through ``repro.obs`` (``obs.timed``); run under ``REPRO_OBS=1`` to
also see the engine's ``grid.block`` spans and ``faults.*`` counters.

  PYTHONPATH=src python benchmarks/faults_bench.py
  PYTHONPATH=src python -m benchmarks.run faults
  make faults-bench
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

import jax
import numpy as np

from repro import faults, obs
from repro.core.simulate import simulate_grid
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import QuickscalingTwin, SimpleTwin, make_twin

SIZES = (1024, 65536)       # expanded rows (base scenarios x futures)
N_FUTURES = 4
N_TRAFFICS = 16
BLOCK = 4096                # aggregate-mode scenario block
REPEATS = 2
OUT_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_faults.json"

SCHEDULE = faults.FaultSchedule(
    specs=(faults.outage(rate_per_year=6, duration_hours=(1, 4)),
           faults.disconnect(rate_per_year=12,
                             disconnect_frac=(0.2, 0.5)),
           faults.brownout(rate_per_year=8, capacity_mult=(0.3, 0.7)),
           faults.burst(rate_per_year=8, load_mult=(1.5, 3.0))),
    n_futures=N_FUTURES, seed=0)


def _twins(n: int) -> List:
    eight = [
        SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
        QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
        make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, max_instances=32, scale_up_hours=3),
        make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
                  base_latency_s=0.15, queue_cap_hours=2),
        make_twin("batch", "batch_window", max_rps=6.15,
                  usd_per_hour=0.0703, base_latency_s=0.06,
                  window_hours=6),
        SimpleTwin("fifo-lean", 1.2, 0.005, 0.2),
        QuickscalingTwin("quick-fat", 3.0, 0.016, 0.1),
        SimpleTwin("fifo-fat", 3.9, 0.0164, 0.1),
    ]
    return [eight[i % 8] for i in range(n)]


def _grid(n_scen: int):
    matrix = np.stack(
        [TrafficModel.honda_default(f"g{g:.3f}", R=3.5,
                                    G=float(g)).hourly_loads()
         for g in np.linspace(1.0, 1.7, N_TRAFFICS)]).astype(np.float32)
    index = (np.arange(n_scen, dtype=np.int32) // 8) % N_TRAFFICS
    return _twins(n_scen), matrix, index


def _time_best(fn, repeats: int = REPEATS,
               label: str = "bench.faults") -> float:
    fn()                                  # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        with obs.timed(label) as tm:
            fn()
        best = min(best, tm.elapsed)
    return best * 1e3


def bench(sizes=SIZES, repeats: int = REPEATS) -> Dict:
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    rows = []
    for n in sizes:
        n_base = n // N_FUTURES
        b_twins, matrix, b_index = _grid(n)
        f_twins, _, f_index = _grid(n_base)
        block = min(BLOCK, n)

        def benign():
            return simulate_grid(b_twins, slo=slo, return_series=False,
                                 load_matrix=matrix, load_index=b_index,
                                 scenario_block=block)

        def chaos():
            return simulate_grid(f_twins, slo=slo, return_series=False,
                                 load_matrix=matrix, load_index=f_index,
                                 scenario_block=block, faults=SCHEDULE)

        sims = chaos()                      # warm + acceptance sample
        assert len(sims) == n, (len(sims), n)
        assert any(s.fault_hours > 0 for s in sims)
        benign_ms = _time_best(benign, repeats,
                               label="bench.faults_benign")
        chaos_ms = _time_best(chaos, repeats,
                              label="bench.faults_chaos")
        rows.append({
            "rows": n, "base_scenarios": n_base, "futures": N_FUTURES,
            "hours": int(matrix.shape[1]), "scenario_block": block,
            "benign_ms": round(benign_ms, 1),
            "chaos_ms": round(chaos_ms, 1),
            "overhead": round(chaos_ms / benign_ms, 3),
            "fault_rows_pct": round(
                100.0 * sum(s.fault_hours > 0 for s in sims) / n, 1),
        })
        del sims
    out = {"device": jax.devices()[0].platform, "repeats": repeats,
           "schedule": [s.name for s in SCHEDULE.specs],
           "parity": "empty schedule bit-identical to faults=None "
                     "(tests/test_faults.py)",
           "sizes": rows}
    OUT_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def main() -> List[str]:
    out = bench()
    lines = []
    for r in out["sizes"]:
        lines.append(
            f"faults/rows{r['rows']},{r['chaos_ms'] * 1e3:.0f},"
            f"overhead={r['overhead']}x_vs_benign;"
            f"futures={r['futures']};block={r['scenario_block']}")
    lines.append(f"faults/json,0,wrote={OUT_JSON.name}")
    return lines


if __name__ == "__main__":
    print(json.dumps(bench(), indent=2, sort_keys=True))

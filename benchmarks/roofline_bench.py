"""Roofline benchmark: the 40-cell (arch x shape) table from the dry-run
cache (launch/dryrun.py must have populated experiments/dryrun)."""
from __future__ import annotations

import os
from typing import List

from repro.launch.roofline import analyze_all, rows

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def main() -> List[str]:
    if not os.path.isdir(OUT_DIR) or not os.listdir(OUT_DIR):
        return ["roofline/missing,0,run `python -m repro.launch.dryrun` first"]
    cells = analyze_all(OUT_DIR, "single")
    lines = []
    for c in cells:
        if c.status == "skipped":
            lines.append(f"roofline/{c.arch}/{c.shape},0,skipped")
            continue
        if c.status != "ok":
            lines.append(f"roofline/{c.arch}/{c.shape},0,{c.status}")
            continue
        lines.append(
            f"roofline/{c.arch}/{c.shape},{c.bound_s*1e6:.1f},"
            f"bound={c.bound};frac={c.roofline_fraction:.3f};"
            f"useful={c.useful_ratio:.3f};fits={c.fits_hbm}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)

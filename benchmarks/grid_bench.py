"""What-if grid microbenchmarks: looped vs vmapped, XLA vs Pallas, and
series vs streaming-aggregate.

The seed ran ``run_grid`` as a Python loop of one jitted scan per scenario;
the TwinPolicy engine stacks the whole (twin x traffic) grid and runs it as
one vmap-over-scan dispatch. ``bench`` times both on a 64-scenario grid
(8 twins spanning all five policies x 8 traffic forecasts) and emits a JSON
record with the measured speedup.

``bench_pallas`` times the two grid *backends* against each other — the
XLA vmapped ``lax.switch`` scan vs the fused Pallas scenario-grid kernel
(interpret mode on this CPU container; the same structure compiles for TPU
lanes) — at N in {64, 256, 1024} scenarios, and writes
``BENCH_grid_pallas.json``.

``bench_stream`` times the two result *modes* end to end through
``simulate_grid`` — the [N, T]-series path (device series + f64 host
conversion + per-scenario numpy summaries) vs the streaming-aggregate
path (stats folded into the scan carry, chunked ``lax.map`` dispatch, one
vectorized summary pass) — at N in {1024, 8192, 65536} full-year
scenarios, and writes ``BENCH_grid_stream.json``. The series path only
runs where its five [N, 8736] f32 + f64 buffers fit comfortably
(N <= SERIES_MAX_N); the aggregate path streams every size through
scenario blocks, so 65536 scenarios complete on this CPU container.

``bench_shard`` sweeps the sharded block engine — the donated async
policy-uniform block dispatch of ``core.simulate._grid_agg_dispatch``,
single-device and over a 1/2/4-device scenario mesh — at N in
{65536, 262144, 1048576} full-year scenarios, and writes
``BENCH_grid_shard.json``. On this 1-core CPU container the fake host
devices share the core, so the mesh rows document the sharded
*structure* (and its bit-parity with the one-device engine); the
single-device row is the wall-clock number, measured against the prior
serial ``lax.map`` engine recorded in ``BENCH_grid_stream.json``.

``bench_device_hist`` times the fully device-resident aggregate engine
(the in-graph f64 ``segment_sum`` latency histogram replacing the host
``np.bincount`` drain, no [B, T] latency panel staged or copied off
device, bitwise-duplicate scenario rows deduped at dispatch) — at N in
{1024, 65536, 1048576} full-year scenarios, single-device and over a
1/2/4-device scenario mesh, plus a jittered all-distinct control row
where dedup cannot fire — and writes ``BENCH_grid_device.json``, with
the speedup measured against the PR 6 host-binned devices=1 rows
recorded in ``BENCH_grid_shard.json``.

  PYTHONPATH=src python benchmarks/grid_bench.py           # looped/vmapped
  PYTHONPATH=src python benchmarks/grid_bench.py pallas    # backend sweep
  PYTHONPATH=src python benchmarks/grid_bench.py stream    # series vs agg
  PYTHONPATH=src python benchmarks/grid_bench.py shard     # sharded engine
  PYTHONPATH=src python benchmarks/grid_bench.py device    # device-res hist
  PYTHONPATH=src python -m benchmarks.run grid             # looped/vmapped
  PYTHONPATH=src python -m benchmarks.run grid-pallas      # backend sweep
  PYTHONPATH=src python -m benchmarks.run grid-stream      # series vs agg
  PYTHONPATH=src python -m benchmarks.run grid-shard       # sharded engine
  PYTHONPATH=src python -m benchmarks.run grid-device      # device-res hist
  make grid-bench-pallas / grid-bench-stream / grid-bench-shard /
       grid-bench-device

Every timing loop records through ``repro.obs`` (``obs.timed`` spans) —
the JSON rows serialize those spans' best-of numbers, and running any
sweep under ``REPRO_OBS=1`` additionally surfaces the engine's own
``grid.block`` / ``grid.round`` spans next to them (``obs.render()``).
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
from typing import Dict, List

# the shard/device sweeps need multiple host devices, and XLA only reads
# this before its first backend init — so it must be set before jax
# imports anywhere in the process (harmless for every other sweep)
if {"shard", "grid-shard", "device", "grid-device"} & set(sys.argv[1:]):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.simulate import _grid_scan, _grid_scan_xla, simulate_grid
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import (QuickscalingTwin, SimpleTwin, make_twin,
                             policy_onehot, registry_version)
from repro.kernels.policy_scan import policy_grid_scan

N_TWINS = 8
N_TRAFFICS = 8
REPEATS = 5
PALLAS_SIZES = (64, 256, 1024)
STREAM_SIZES = (1024, 8192, 65536)
SHARD_SIZES = (65536, 262144, 1048576)
SHARD_MESHES = (1, 2, 4)
DEVICE_SIZES = (1024, 65536, 1048576)
SERIES_MAX_N = 1024        # five [N, 8736] f32+f64 series stay <1 GB here
STREAM_BLOCK = 4096        # aggregate-mode lax.map scenario block
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_grid_pallas.json"
STREAM_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_grid_stream.json"
SHARD_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_grid_shard.json"
DEVICE_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_grid_device.json"


def _grid(n_twins: int = N_TWINS, n_traffics: int = N_TRAFFICS):
    twins = [
        SimpleTwin("block", 1.9512, 0.0082, 0.15),
        SimpleTwin("non-block", 6.15, 0.0703, 0.06),
        SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29),
        QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
        make_twin("auto-fast", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=1),
        make_twin("auto-slow", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=6),
        make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
                  base_latency_s=0.15, queue_cap_hours=2),
        make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
                  base_latency_s=0.06, window_hours=6),
    ][:n_twins]
    traffics = [TrafficModel.honda_default(f"g{g:.2f}", R=3.5, G=g)
                for g in np.linspace(1.0, 1.7, n_traffics)]
    grid_twins, loads = [], []
    for tr in traffics:
        hl = tr.hourly_loads()
        for tw in twins:
            grid_twins.append(tw)
            loads.append(hl)
    return grid_twins, np.stack(loads).astype(np.float32)


def _kernel_args(twins, loads):
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return loads, params, idx, registry_version()


def bench() -> Dict:
    twins, loads = _grid()
    loads_j, params, idx, ver = _kernel_args(twins, loads)
    n = len(twins)

    # vmapped: one dispatch over the stacked batch
    def vmapped():
        out = _grid_scan(loads_j, params, idx, ver)
        jax.block_until_ready(out)

    # looped: the seed's shape — one batch-of-1 kernel call per scenario
    def looped():
        for i in range(n):
            out = _grid_scan(loads_j[i:i + 1], params[i:i + 1],
                             idx[i:i + 1], ver)
        jax.block_until_ready(out)

    vmapped(), looped()          # warm both jit caches
    t_vm, t_loop = [], []
    for _ in range(REPEATS):
        with obs.timed("bench.grid_vmapped", scenarios=n) as tm:
            vmapped()
        t_vm.append(tm.elapsed)
        with obs.timed("bench.grid_looped", scenarios=n) as tm:
            looped()
        t_loop.append(tm.elapsed)
    vm_ms = min(t_vm) * 1e3
    loop_ms = min(t_loop) * 1e3
    return {
        "scenarios": n,
        "hours": int(loads.shape[1]),
        "looped_ms": round(loop_ms, 3),
        "vmapped_ms": round(vm_ms, 3),
        "speedup": round(loop_ms / vm_ms, 2),
        "device": jax.devices()[0].platform,
    }


def _time_best(fn, repeats: int = REPEATS,
               label: str = "bench.grid") -> float:
    fn()                                  # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        with obs.timed(label) as tm:
            fn()
        best = min(best, tm.elapsed)
    return best * 1e3


def bench_pallas(sizes=PALLAS_SIZES, repeats: int = REPEATS) -> Dict:
    """XLA vmapped-switch backend vs fused Pallas scenario-grid kernel.

    On this CPU container the kernel runs in interpret mode, so the
    numbers measure the fused-scan structure (one pallas_call, scenarios
    on lanes, carry resident) rather than TPU silicon; parity with the
    XLA path is asserted on every size before timing.
    """
    rows = []
    for n in sizes:
        twins, loads = _grid(n_twins=8, n_traffics=-(-n // 8))
        twins, loads = twins[:n], loads[:n]
        loads, params, idx, ver = _kernel_args(twins, loads)
        loads_j, params_j = jnp.asarray(loads), jnp.asarray(params)
        idx_j = jnp.asarray(idx)
        onehot_j = jnp.asarray(policy_onehot(idx))

        def xla():
            jax.block_until_ready(
                _grid_scan_xla(loads_j, params_j, idx_j, ver, 1.0))

        def pallas():
            jax.block_until_ready(
                policy_grid_scan(loads_j, params_j, onehot_j, 1.0,
                                 interpret=True))

        # parity first (1e-5 relative on every series), then wall-clock
        _, outs_x = _grid_scan_xla(loads_j, params_j, idx_j, ver, 1.0)
        _, outs_p = policy_grid_scan(loads_j, params_j, onehot_j, 1.0,
                                     interpret=True)
        for a, b in zip(outs_x, outs_p):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)

        xla_ms = _time_best(xla, repeats)
        pallas_ms = _time_best(pallas, repeats)
        rows.append({"scenarios": n, "hours": int(loads.shape[1]),
                     "xla_ms": round(xla_ms, 3),
                     "pallas_interpret_ms": round(pallas_ms, 3),
                     "pallas_over_xla": round(pallas_ms / xla_ms, 3)})
    out = {"device": jax.devices()[0].platform, "repeats": repeats,
           "mode": "interpret", "parity_rtol": 1e-5, "sizes": rows}
    BENCH_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def _stream_grid(n: int, n_traffics: int = 16):
    """n scenarios as twins + a [n_traffics, 8736] load matrix + index map
    (the O(K*T + N) host encoding ``whatif.run_grid`` uses) — the 8 bench
    twins cycled over growth-swept traffic forecasts."""
    twins8, _ = _grid(n_twins=8, n_traffics=1)
    twins = [twins8[i % 8] for i in range(n)]
    matrix = np.stack([TrafficModel.honda_default(f"g{g:.3f}", R=3.5,
                                                  G=float(g)).hourly_loads()
                       for g in np.linspace(1.0, 1.7, n_traffics)]).astype(
        np.float32)
    index = (np.arange(n, dtype=np.int32) // 8) % n_traffics
    return twins, matrix, index


def bench_stream(sizes=STREAM_SIZES, repeats: int = 3) -> Dict:
    """Series vs streaming-aggregate ``simulate_grid``, end to end.

    Both modes run the same XLA switch-scan policy math over the same
    (load matrix, index) grid with a 4h latency SLO; what differs is
    everything around it — five [N, 8736] output series + f64 conversion
    + a per-scenario numpy summary loop, vs O(N) in-carry aggregates +
    one vectorized summary pass. Aggregate wall-clock must come out
    >= 2x faster at N = 1024 (the acceptance bar); scalar outputs are
    asserted bit-identical before timing wherever both modes run.
    """
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    rows = []
    for n in sizes:
        twins, matrix, index = _stream_grid(n)
        block = min(STREAM_BLOCK, n)

        def agg():
            return simulate_grid(twins, slo=slo, return_series=False,
                                 load_matrix=matrix, load_index=index,
                                 scenario_block=block)

        row = {"scenarios": n, "hours": int(matrix.shape[1]),
               "scenario_block": block}
        sims_a = agg()                          # warm + parity sample
        agg_ms = _time_best(agg, repeats)
        row["aggregate_ms"] = round(agg_ms, 1)
        if n <= SERIES_MAX_N:
            def series():
                return simulate_grid(twins, slo=slo, return_series=True,
                                     load_matrix=matrix, load_index=index)

            sims_s = series()
            for s, a in zip(sims_s, sims_a):
                assert s.total_cost_usd == a.total_cost_usd, s.name
                assert s.max_throughput_rph == a.max_throughput_rph
                assert s.slo_met == a.slo_met
            series_ms = _time_best(series, repeats)
            row["series_ms"] = round(series_ms, 1)
            row["agg_speedup"] = round(series_ms / agg_ms, 2)
        else:
            row["series_ms"] = None             # would not fit sensibly
            row["agg_speedup"] = None
        rows.append(row)
        del sims_a
    out = {"device": jax.devices()[0].platform, "repeats": repeats,
           "series_max_n": SERIES_MAX_N, "slo": "latency<=4h@95%",
           "parity": "scalar outputs bit-identical where both modes ran",
           "sizes": rows}
    STREAM_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def _shard_grid(n: int, n_traffics: int = 16):
    """The shard sweep's raw dispatch operands — the ``_stream_grid``
    scenario mix without materializing an n-element twin list (at a
    million scenarios the engine arrays are the honest cost; a Python
    object list is not)."""
    twins8, _ = _grid(n_twins=8, n_traffics=1)
    reps = -(-n // 8)
    params = np.tile(np.stack([tw.padded_params() for tw in twins8]),
                     (reps, 1))[:n].astype(np.float32)
    idx = np.tile(np.asarray([tw.policy_index for tw in twins8], np.int32),
                  reps)[:n]
    matrix = np.stack([TrafficModel.honda_default(f"g{g:.3f}", R=3.5,
                                                  G=float(g)).hourly_loads()
                       for g in np.linspace(1.0, 1.7, n_traffics)]).astype(
        np.float32)
    index = (np.arange(n, dtype=np.int32) // 8) % n_traffics
    return matrix, index, params, idx


def bench_shard(sizes=SHARD_SIZES, meshes=SHARD_MESHES) -> Dict:
    """Sharded million-scenario aggregate engine: N x mesh sweep.

    Every (N, devices) cell runs the full streaming dispatch end to end —
    policy-uniform block plan, donated async device scans, overlapped
    host histogram binning, scatter back to grid order. devices=1 is the
    single-device engine; devices>1 shards one block per device per
    round through ``shard_map``. Bit-parity across mesh sizes is
    asserted at the smallest N before any timing is recorded.
    """
    from repro.core.simulate import _grid_agg_dispatch, agg_auto_block
    avail = jax.device_count()
    usable = [d for d in meshes if d <= avail]
    skipped = [d for d in meshes if d > avail]
    slo_limit = 4.0 * 3600.0
    block = agg_auto_block(8736)

    def dispatch(matrix, index, params, idx, d):
        return _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                  slo_limit, 0, None,
                                  devices=None if d == 1 else d)

    # warm every mesh's jit cache on a 2x-block grid (same [block] shapes
    # the big sweeps compile to), so the timed runs measure execution
    warm = _shard_grid(2 * block)
    for d in usable:
        dispatch(*warm, d)

    rows = []
    for n in sizes:
        matrix, index, params, idx = _shard_grid(n)
        row = {"scenarios": n, "hours": int(matrix.shape[1]),
               "scenario_block": block, "mesh": {}}
        base = None
        for d in usable:
            with obs.timed("bench.grid_shard", scenarios=n,
                           mesh=d) as tm:
                carry, agg = dispatch(matrix, index, params, idx, d)
            row["mesh"][str(d)] = round(tm.elapsed * 1e3, 1)
            if n == sizes[0]:
                if base is None:
                    base = (carry, agg)
                else:
                    np.testing.assert_array_equal(carry, base[0])
                    np.testing.assert_array_equal(agg, base[1])
        del carry, agg, base
        rows.append(row)
    baseline = None
    if STREAM_JSON.exists():      # the prior serial lax.map engine's time
        for r in json.loads(STREAM_JSON.read_text())["sizes"]:
            if r["scenarios"] == sizes[0] and r.get("aggregate_ms"):
                baseline = {"scenarios": sizes[0],
                            "lax_map_aggregate_ms": r["aggregate_ms"]}
    out = {"device": jax.devices()[0].platform, "device_count": avail,
           "meshes": usable, "meshes_skipped_no_devices": skipped,
           "scenario_block": block,
           "parity": "mesh results bit-identical at the smallest N",
           "note": "fake host devices share this container's one core; "
                   "mesh>1 rows document sharded structure, devices=1 is "
                   "the wall-clock number",
           "serial_baseline": baseline, "sizes": rows}
    SHARD_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def bench_device_hist(sizes=DEVICE_SIZES, meshes=SHARD_MESHES) -> Dict:
    """Fully device-resident aggregate engine: N x mesh sweep vs PR 6.

    Same dispatch shape as ``bench_shard`` (policy-uniform blocks,
    donated accumulators, ``shard_map`` rounds for devices>1), but the
    engine under it no longer stages a [B, T] latency panel or drains it
    to the host for ``np.bincount`` binning — the load-weighted
    quarter-octave histogram accumulates in-graph as an exact f64
    ``segment_sum`` per time chunk, and blocks are sized by the
    panel-free footprint. The dispatch also dedups bitwise-identical
    scenario rows before simulating — this sweep's grid tiles 8 twins
    over 8 traffic ramps, so every N collapses to the same 128 distinct
    scenarios; ``unique_scenarios`` records that per row, and the
    ``distinct`` row jitters every param vector so dedup CANNOT fire
    and the raw no-dedup engine time is on record next to the tiled
    ones. The speedup rows compare end to end against the host-binned
    devices=1 times recorded in ``BENCH_grid_shard.json`` (same
    container, same tiled scenario mix — the PR 6 engine had no dedup
    and simulated every row). Bit-parity across mesh sizes is asserted
    at the smallest N before any timing is recorded.
    """
    from repro.core.simulate import (_dedup_rows, _grid_agg_dispatch,
                                     agg_auto_block)
    avail = jax.device_count()
    usable = [d for d in meshes if d <= avail]
    skipped = [d for d in meshes if d > avail]
    slo_limit = 4.0 * 3600.0
    block = agg_auto_block(8736)

    def dispatch(matrix, index, params, idx, d):
        return _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                  slo_limit, 0, None,
                                  devices=None if d == 1 else d)

    # warm every mesh's jit cache on a 2x-block grid (same [block] shapes
    # the big sweeps compile to), so the timed runs measure execution
    warm = _shard_grid(2 * block)
    for d in usable:
        dispatch(*warm, d)

    baseline = {}
    if SHARD_JSON.exists():   # PR 6 host-binned engine, same scenario mix
        for r in json.loads(SHARD_JSON.read_text())["sizes"]:
            if r.get("mesh", {}).get("1"):
                baseline[r["scenarios"]] = r["mesh"]["1"]

    rows = []
    for n in sizes:
        matrix, index, params, idx = _shard_grid(n)
        dd = _dedup_rows(index, params, idx)
        row = {"scenarios": n, "hours": int(matrix.shape[1]),
               "scenario_block": block,
               "unique_scenarios": n if dd is None else int(len(dd[0])),
               "mesh": {}}
        del dd
        base = None
        for d in usable:
            with obs.timed("bench.grid_device", scenarios=n,
                           mesh=d) as tm:
                carry, agg = dispatch(matrix, index, params, idx, d)
            row["mesh"][str(d)] = round(tm.elapsed * 1e3, 1)
            if n == sizes[0]:
                if base is None:
                    base = (carry, agg)
                else:
                    np.testing.assert_array_equal(carry, base[0])
                    np.testing.assert_array_equal(agg, base[1])
        del carry, agg, base
        if n in baseline:
            row["host_binned_d1_ms"] = baseline[n]
            row["speedup_vs_host_binned"] = round(
                baseline[n] / row["mesh"]["1"], 2)
        rows.append(row)

    # the no-dedup control: jitter every param vector so each of the
    # 1024 rows is bitwise distinct and the engine simulates all of them
    n = 1024
    matrix, index, params, idx = _shard_grid(n)
    params = (params
              * (1.0 + np.arange(n, dtype=np.float32)[:, None] * 1e-5))
    assert _dedup_rows(index, params, idx) is None
    dispatch(matrix, index, params, idx, 1)      # warm this shape
    with obs.timed("bench.grid_device", scenarios=n, mesh=1,
                   distinct=True) as tm:
        dispatch(matrix, index, params, idx, 1)
    rows.append({"scenarios": n, "hours": int(matrix.shape[1]),
                 "scenario_block": block, "distinct": True,
                 "unique_scenarios": n,
                 "mesh": {"1": round(tm.elapsed * 1e3, 1)}})

    out = {"device": jax.devices()[0].platform, "device_count": avail,
           "meshes": usable, "meshes_skipped_no_devices": skipped,
           "scenario_block": block,
           "parity": "mesh results bit-identical at the smallest N",
           "note": "device-resident f64 segment_sum histogram, no [B,T] "
                   "panel, no host binning; the dispatch dedups bitwise-"
                   "duplicate scenario rows, and this tiled sweep "
                   "collapses to unique_scenarios distinct years per row "
                   "(the distinct row disables that by construction); "
                   "speedup vs the PR 6 host-binned no-dedup devices=1 "
                   "rows in BENCH_grid_shard.json",
           "sizes": rows}
    DEVICE_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def main() -> List[str]:
    r = bench()
    return [f"grid/looped_{r['scenarios']}x,{r['looped_ms'] * 1e3:.0f},"
            f"per-scenario-dispatch",
            f"grid/vmapped_{r['scenarios']}x,{r['vmapped_ms'] * 1e3:.0f},"
            f"speedup={r['speedup']}x;{json.dumps(r, sort_keys=True)}"]


def main_pallas() -> List[str]:
    r = bench_pallas()
    lines = []
    for row in r["sizes"]:
        n = row["scenarios"]
        lines.append(f"grid/xla_{n}x,{row['xla_ms'] * 1e3:.0f},"
                     f"vmapped-switch-scan")
        lines.append(f"grid/pallas_{n}x,{row['pallas_interpret_ms'] * 1e3:.0f},"
                     f"interpret;ratio={row['pallas_over_xla']}")
    lines.append(f"grid/pallas_json,0,wrote={BENCH_JSON.name}")
    return lines


def main_stream() -> List[str]:
    r = bench_stream()
    lines = []
    for row in r["sizes"]:
        n = row["scenarios"]
        lines.append(f"grid/agg_{n}x,{row['aggregate_ms'] * 1e3:.0f},"
                     f"streaming-aggregate;block={row['scenario_block']}")
        if row["series_ms"] is not None:
            lines.append(f"grid/series_{n}x,{row['series_ms'] * 1e3:.0f},"
                         f"full-series;agg_speedup={row['agg_speedup']}x")
        else:
            lines.append(f"grid/series_{n}x,0,skipped;over-series-budget")
    lines.append(f"grid/stream_json,0,wrote={STREAM_JSON.name}")
    return lines


def main_shard() -> List[str]:
    r = bench_shard()
    lines = []
    for row in r["sizes"]:
        n = row["scenarios"]
        for d, ms in sorted(row["mesh"].items(), key=lambda kv: int(kv[0])):
            lines.append(f"grid/shard_{n}x_d{d},{ms * 1e3:.0f},"
                         f"block={row['scenario_block']}")
    if r["serial_baseline"]:
        b = r["serial_baseline"]
        lines.append(f"grid/shard_baseline_{b['scenarios']}x,"
                     f"{b['lax_map_aggregate_ms'] * 1e3:.0f},"
                     f"prior-serial-lax-map")
    lines.append(f"grid/shard_json,0,wrote={SHARD_JSON.name}")
    return lines


def main_device() -> List[str]:
    r = bench_device_hist()
    lines = []
    for row in r["sizes"]:
        n = row["scenarios"]
        tag = "_distinct" if row.get("distinct") else ""
        for d, ms in sorted(row["mesh"].items(), key=lambda kv: int(kv[0])):
            lines.append(f"grid/device_{n}x{tag}_d{d},{ms * 1e3:.0f},"
                         f"block={row['scenario_block']};"
                         f"unique={row['unique_scenarios']}")
        if row.get("host_binned_d1_ms"):
            lines.append(f"grid/device_baseline_{n}x,"
                         f"{row['host_binned_d1_ms'] * 1e3:.0f},"
                         f"host-binned;speedup="
                         f"{row['speedup_vs_host_binned']}x")
    lines.append(f"grid/device_json,0,wrote={DEVICE_JSON.name}")
    return lines


if __name__ == "__main__":
    if "device" in sys.argv[1:]:
        print(json.dumps(bench_device_hist(), indent=2, sort_keys=True))
    elif "shard" in sys.argv[1:]:
        print(json.dumps(bench_shard(), indent=2, sort_keys=True))
    elif "pallas" in sys.argv[1:]:
        print(json.dumps(bench_pallas(), indent=2, sort_keys=True))
    elif "stream" in sys.argv[1:]:
        print(json.dumps(bench_stream(), indent=2, sort_keys=True))
    else:
        print(json.dumps(bench(), indent=2, sort_keys=True))

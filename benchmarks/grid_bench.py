"""Looped vs vmapped what-if grid microbenchmark.

The seed ran ``run_grid`` as a Python loop of one jitted scan per scenario;
the TwinPolicy engine stacks the whole (twin x traffic) grid and runs it as
one vmap-over-scan dispatch. This benchmark times both on a 64-scenario
grid (8 twins spanning all five policies x 8 traffic forecasts) and emits a
JSON record with the measured speedup.

  PYTHONPATH=src python benchmarks/grid_bench.py
  PYTHONPATH=src python -m benchmarks.run grid
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.simulate import _grid_scan
from repro.core.traffic import TrafficModel
from repro.core.twin import (QuickscalingTwin, SimpleTwin, make_twin,
                             registry_version)

N_TWINS = 8
N_TRAFFICS = 8
REPEATS = 5


def _grid():
    twins = [
        SimpleTwin("block", 1.9512, 0.0082, 0.15),
        SimpleTwin("non-block", 6.15, 0.0703, 0.06),
        SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29),
        QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
        make_twin("auto-fast", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=1),
        make_twin("auto-slow", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=6),
        make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
                  base_latency_s=0.15, queue_cap_hours=2),
        make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
                  base_latency_s=0.06, window_hours=6),
    ][:N_TWINS]
    traffics = [TrafficModel.honda_default(f"g{g:.2f}", R=3.5, G=g)
                for g in np.linspace(1.0, 1.7, N_TRAFFICS)]
    grid_twins, loads = [], []
    for tr in traffics:
        hl = tr.hourly_loads()
        for tw in twins:
            grid_twins.append(tw)
            loads.append(hl)
    return grid_twins, np.stack(loads).astype(np.float32)


def _kernel_args(twins, loads):
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return loads, params, idx, registry_version()


def bench() -> Dict:
    twins, loads = _grid()
    loads_j, params, idx, ver = _kernel_args(twins, loads)
    n = len(twins)

    # vmapped: one dispatch over the stacked batch
    def vmapped():
        out = _grid_scan(loads_j, params, idx, ver)
        jax.block_until_ready(out)

    # looped: the seed's shape — one batch-of-1 kernel call per scenario
    def looped():
        for i in range(n):
            out = _grid_scan(loads_j[i:i + 1], params[i:i + 1],
                             idx[i:i + 1], ver)
        jax.block_until_ready(out)

    vmapped(), looped()          # warm both jit caches
    t_vm, t_loop = [], []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        vmapped()
        t_vm.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        looped()
        t_loop.append(time.perf_counter() - t0)
    vm_ms = min(t_vm) * 1e3
    loop_ms = min(t_loop) * 1e3
    return {
        "scenarios": n,
        "hours": int(loads.shape[1]),
        "looped_ms": round(loop_ms, 3),
        "vmapped_ms": round(vm_ms, 3),
        "speedup": round(loop_ms / vm_ms, 2),
        "device": jax.devices()[0].platform,
    }


def main() -> List[str]:
    r = bench()
    return [f"grid/looped_{r['scenarios']}x,{r['looped_ms'] * 1e3:.0f},"
            f"per-scenario-dispatch",
            f"grid/vmapped_{r['scenarios']}x,{r['vmapped_ms'] * 1e3:.0f},"
            f"speedup={r['speedup']}x;{json.dumps(r, sort_keys=True)}"]


if __name__ == "__main__":
    print(json.dumps(bench(), indent=2, sort_keys=True))

"""What-if grid microbenchmarks: looped vs vmapped, and XLA vs Pallas.

The seed ran ``run_grid`` as a Python loop of one jitted scan per scenario;
the TwinPolicy engine stacks the whole (twin x traffic) grid and runs it as
one vmap-over-scan dispatch. ``bench`` times both on a 64-scenario grid
(8 twins spanning all five policies x 8 traffic forecasts) and emits a JSON
record with the measured speedup.

``bench_pallas`` times the two grid *backends* against each other — the
XLA vmapped ``lax.switch`` scan vs the fused Pallas scenario-grid kernel
(interpret mode on this CPU container; the same structure compiles for TPU
lanes) — at N in {64, 256, 1024} scenarios, and writes
``BENCH_grid_pallas.json``.

  PYTHONPATH=src python benchmarks/grid_bench.py           # looped/vmapped
  PYTHONPATH=src python benchmarks/grid_bench.py pallas    # backend sweep
  PYTHONPATH=src python -m benchmarks.run grid             # looped/vmapped
  PYTHONPATH=src python -m benchmarks.run grid-pallas      # backend sweep
  make grid-bench-pallas
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulate import _grid_scan, _grid_scan_xla
from repro.core.traffic import TrafficModel
from repro.core.twin import (QuickscalingTwin, SimpleTwin, make_twin,
                             policy_onehot, registry_version)
from repro.kernels.policy_scan import policy_grid_scan

N_TWINS = 8
N_TRAFFICS = 8
REPEATS = 5
PALLAS_SIZES = (64, 256, 1024)
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_grid_pallas.json"


def _grid(n_twins: int = N_TWINS, n_traffics: int = N_TRAFFICS):
    twins = [
        SimpleTwin("block", 1.9512, 0.0082, 0.15),
        SimpleTwin("non-block", 6.15, 0.0703, 0.06),
        SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29),
        QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
        make_twin("auto-fast", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=1),
        make_twin("auto-slow", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, scale_up_hours=6),
        make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
                  base_latency_s=0.15, queue_cap_hours=2),
        make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
                  base_latency_s=0.06, window_hours=6),
    ][:n_twins]
    traffics = [TrafficModel.honda_default(f"g{g:.2f}", R=3.5, G=g)
                for g in np.linspace(1.0, 1.7, n_traffics)]
    grid_twins, loads = [], []
    for tr in traffics:
        hl = tr.hourly_loads()
        for tw in twins:
            grid_twins.append(tw)
            loads.append(hl)
    return grid_twins, np.stack(loads).astype(np.float32)


def _kernel_args(twins, loads):
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return loads, params, idx, registry_version()


def bench() -> Dict:
    twins, loads = _grid()
    loads_j, params, idx, ver = _kernel_args(twins, loads)
    n = len(twins)

    # vmapped: one dispatch over the stacked batch
    def vmapped():
        out = _grid_scan(loads_j, params, idx, ver)
        jax.block_until_ready(out)

    # looped: the seed's shape — one batch-of-1 kernel call per scenario
    def looped():
        for i in range(n):
            out = _grid_scan(loads_j[i:i + 1], params[i:i + 1],
                             idx[i:i + 1], ver)
        jax.block_until_ready(out)

    vmapped(), looped()          # warm both jit caches
    t_vm, t_loop = [], []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        vmapped()
        t_vm.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        looped()
        t_loop.append(time.perf_counter() - t0)
    vm_ms = min(t_vm) * 1e3
    loop_ms = min(t_loop) * 1e3
    return {
        "scenarios": n,
        "hours": int(loads.shape[1]),
        "looped_ms": round(loop_ms, 3),
        "vmapped_ms": round(vm_ms, 3),
        "speedup": round(loop_ms / vm_ms, 2),
        "device": jax.devices()[0].platform,
    }


def _time_best(fn, repeats: int = REPEATS) -> float:
    fn()                                  # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_pallas(sizes=PALLAS_SIZES, repeats: int = REPEATS) -> Dict:
    """XLA vmapped-switch backend vs fused Pallas scenario-grid kernel.

    On this CPU container the kernel runs in interpret mode, so the
    numbers measure the fused-scan structure (one pallas_call, scenarios
    on lanes, carry resident) rather than TPU silicon; parity with the
    XLA path is asserted on every size before timing.
    """
    rows = []
    for n in sizes:
        twins, loads = _grid(n_twins=8, n_traffics=-(-n // 8))
        twins, loads = twins[:n], loads[:n]
        loads, params, idx, ver = _kernel_args(twins, loads)
        loads_j, params_j = jnp.asarray(loads), jnp.asarray(params)
        idx_j = jnp.asarray(idx)
        onehot_j = jnp.asarray(policy_onehot(idx))

        def xla():
            jax.block_until_ready(
                _grid_scan_xla(loads_j, params_j, idx_j, ver, 1.0))

        def pallas():
            jax.block_until_ready(
                policy_grid_scan(loads_j, params_j, onehot_j, 1.0,
                                 interpret=True))

        # parity first (1e-5 relative on every series), then wall-clock
        _, outs_x = _grid_scan_xla(loads_j, params_j, idx_j, ver, 1.0)
        _, outs_p = policy_grid_scan(loads_j, params_j, onehot_j, 1.0,
                                     interpret=True)
        for a, b in zip(outs_x, outs_p):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-5, atol=1e-5)

        xla_ms = _time_best(xla, repeats)
        pallas_ms = _time_best(pallas, repeats)
        rows.append({"scenarios": n, "hours": int(loads.shape[1]),
                     "xla_ms": round(xla_ms, 3),
                     "pallas_interpret_ms": round(pallas_ms, 3),
                     "pallas_over_xla": round(pallas_ms / xla_ms, 3)})
    out = {"device": jax.devices()[0].platform, "repeats": repeats,
           "mode": "interpret", "parity_rtol": 1e-5, "sizes": rows}
    BENCH_JSON.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return out


def main() -> List[str]:
    r = bench()
    return [f"grid/looped_{r['scenarios']}x,{r['looped_ms'] * 1e3:.0f},"
            f"per-scenario-dispatch",
            f"grid/vmapped_{r['scenarios']}x,{r['vmapped_ms'] * 1e3:.0f},"
            f"speedup={r['speedup']}x;{json.dumps(r, sort_keys=True)}"]


def main_pallas() -> List[str]:
    r = bench_pallas()
    lines = []
    for row in r["sizes"]:
        n = row["scenarios"]
        lines.append(f"grid/xla_{n}x,{row['xla_ms'] * 1e3:.0f},"
                     f"vmapped-switch-scan")
        lines.append(f"grid/pallas_{n}x,{row['pallas_interpret_ms'] * 1e3:.0f},"
                     f"interpret;ratio={row['pallas_over_xla']}")
    lines.append(f"grid/pallas_json,0,wrote={BENCH_JSON.name}")
    return lines


if __name__ == "__main__":
    import sys
    if "pallas" in sys.argv[1:]:
        print(json.dumps(bench_pallas(), indent=2, sort_keys=True))
    else:
        print(json.dumps(bench(), indent=2, sort_keys=True))

"""Multi-start calibration benchmark: fit wall-clock vs restart count K.

The K restarts of a fit run as ONE vmapped grad-of-scan dispatch, so
wall-clock should grow far slower than K (the vmap amortizes dispatch
and the scan dominates). This measures a shed-policy fit on a 72-bin
ramp trace across K, emits the harness CSV rows, and writes the records
to ``BENCH_calibrate.json`` so the perf trajectory has data points.
Timing runs record through ``repro.obs`` (``obs.timed`` spans); under
``REPRO_OBS=1`` the fit's own ``calibrate.fit`` spans appear alongside.

  PYTHONPATH=src python benchmarks/calibrate_bench.py
  PYTHONPATH=src python -m benchmarks.run calibrate
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax

from repro import obs
from repro.calibrate import ObservedTrace, fit
from repro.core.loadpattern import LoadPattern
from repro.core.twin import make_twin

RESTARTS = (1, 8, 32, 64)
STEPS = 200
REPEATS = 3
OUT_JSON = os.environ.get("BENCH_CALIBRATE_JSON", "BENCH_calibrate.json")


def _trace() -> ObservedTrace:
    truth = make_twin("truth", "shed", max_rps=2.0, usd_per_hour=0.05,
                      base_latency_s=0.2, queue_cap_hours=1.5)
    ramp = LoadPattern.ramp("ramp", duration_s=6 * 3600, peak_rate=6.0)
    return ObservedTrace.from_loadpattern(ramp, truth, bin_s=300.0)


def bench() -> Dict:
    trace = _trace()
    records = []
    for k in RESTARTS:
        # compile once outside the timed region (the jit cache is keyed on
        # the [K, PARAM_DIM] shape, so each K compiles its own program)
        fit(trace, "shed", restarts=k, steps=STEPS, seed=0)
        times = []
        for rep in range(REPEATS):
            with obs.timed("bench.calibrate_fit", restarts=k) as tm:
                res = fit(trace, "shed", restarts=k, steps=STEPS,
                          seed=rep)
            times.append(tm.elapsed)
        records.append({"restarts": k, "steps": STEPS,
                        "bins": trace.num_bins,
                        "best_loss": float(res.loss),
                        "fit_ms": round(min(times) * 1e3, 3)})
    base = records[0]["fit_ms"]
    return {
        "device": jax.devices()[0].platform,
        "records": records,
        "ms_per_restart_at_max_k": round(records[-1]["fit_ms"]
                                         / records[-1]["restarts"], 3),
        "scaling_vs_serial": round(
            (records[-1]["restarts"] * base) / records[-1]["fit_ms"], 2),
    }


def main() -> List[str]:
    r = bench()
    with open(OUT_JSON, "w") as f:
        json.dump(r, f, indent=2, sort_keys=True)
    lines = []
    for rec in r["records"]:
        lines.append(f"calibrate/fit_k{rec['restarts']},"
                     f"{rec['fit_ms'] * 1e3:.0f},"
                     f"steps={rec['steps']};bins={rec['bins']}")
    lines.append(f"calibrate/vmap_scaling,"
                 f"{r['ms_per_restart_at_max_k'] * 1e3:.0f},"
                 f"x{r['scaling_vs_serial']}-vs-serial;json={OUT_JSON}")
    return lines


if __name__ == "__main__":
    result = bench()
    with open(OUT_JSON, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
    print(json.dumps(result, indent=2, sort_keys=True))
